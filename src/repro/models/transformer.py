"""Model assembly for all assigned families.

Families:
  dense / moe / audio / vlm : token (+stub patch) embeddings → pre-norm GQA
      attention blocks (MLP or MoE) scanned over layers → norm → LM head.
  ssm    : RWKV6 blocks (attention-free) scanned over layers.
  hybrid : Mamba2 blocks with one *shared-weight* attention block every
      ``attn_every``-th position (Zamba2 pattern) — the shared weights are a
      closure constant of the group scan, so weight sharing is structural.

Tensor-parallel partition specs are chosen per weight at definition time:
head-dim sharding when the head count divides ``tp_size``, otherwise the
contraction (d_model) dim is sharded (row-parallel; GSPMD inserts the
partial-sum all-reduce).  See DESIGN.md §4.

All step functions are pure; caches/recurrent states are explicit
pytrees stacked over layers so ``lax.scan`` threads them.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models.common import (
    ParamDef,
    apply_rope,
    he_normal,
    init_params,
    layer_norm,
    normal_init,
    ones_init,
    rms_norm,
    rope,
    zeros_init,
)
from repro.models.mamba2 import (
    MambaState,
    apply_mamba_block,
    mamba_block_decode,
    mamba_block_defs,
    mamba_n_heads,
)
from repro.models.mlp import apply_mlp, mlp_defs
from repro.models.moe import apply_moe, apply_moe_manual_ep, moe_defs
from repro.models.rwkv6 import (
    RWKVState,
    apply_rwkv_block,
    rwkv_block_decode,
    rwkv_block_defs,
)

PyTree = Any

__all__ = [
    "model_defs",
    "init_model",
    "loss_fn",
    "forward",
    "prefill",
    "decode_step",
    "init_decode_state",
]


# ---------------------------------------------------------------------------
# Param stacking for lax.scan over layers
# ---------------------------------------------------------------------------

def stack_defs(defs: PyTree, n: int) -> PyTree:
    """Prepend a layer axis (n, ...) to every ParamDef (vmapped init)."""

    def _stack(d: ParamDef) -> ParamDef:
        def init(key, shape, dtype):
            keys = jax.random.split(key, n)
            return jax.vmap(lambda k: d.init(k, d.shape, dtype))(keys)

        return ParamDef((n,) + d.shape, init, (None,) + d.spec, d.dtype)

    return jax.tree.map(_stack, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Norm helper (rmsnorm | layernorm)
# ---------------------------------------------------------------------------

def _norm_defs(cfg: ArchConfig, d: int):
    if cfg.norm == "layernorm":
        return {
            "g": ParamDef((d,), ones_init(), (None,), cfg.dtype),
            "b": ParamDef((d,), zeros_init(), (None,), cfg.dtype),
        }
    return {"g": ParamDef((d,), ones_init(), (None,), cfg.dtype)}


def _apply_norm(cfg: ArchConfig, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["g"], p["b"])
    return rms_norm(x, p["g"])


# ---------------------------------------------------------------------------
# Attention block (dense / moe / audio / vlm, and zamba's shared block)
# ---------------------------------------------------------------------------

def _head_spec(n: int, tp: int, tail: tuple = (None,)):
    """('model' on head dim) if divisible else contraction-dim fallback."""
    if n % tp == 0:
        return (None, "model") + tail
    return ("model", None) + tail


def attn_dims(cfg: ArchConfig, tp_size: int) -> tuple[int, int]:
    """(h, kv) actually materialized — padded when cfg.pad_heads (exact
    semantics via masking; see attention.head_padding)."""
    if not (cfg.pad_heads or cfg.pad_kv):
        return cfg.n_heads, cfg.n_kv
    h_pad, kv_pad, _ = attn_lib.head_padding(
        cfg.n_heads, cfg.n_kv, tp_size, pad_kv=cfg.pad_kv
    )
    return h_pad, kv_pad


def _pad_mask(cfg: ArchConfig, params) -> Optional[jax.Array]:
    """Active-head mask (h_pad,) or None when no padding is present."""
    h_pad = params["wq"].shape[1]
    kv_pad = params["wk"].shape[1]
    if h_pad == cfg.n_heads and kv_pad == cfg.n_kv:
        return None
    g_pad = h_pad // kv_pad
    return attn_lib.active_head_mask(cfg.n_heads, cfg.n_kv, h_pad, kv_pad, g_pad)


def attn_block_defs(cfg: ArchConfig, tp_size: int, *, with_ffn: bool = True):
    d, dh, dt = cfg.d_model, cfg.head_dim, cfg.dtype
    h, kv = attn_dims(cfg, tp_size)
    defs = {
        "ln1": _norm_defs(cfg, d),
        "wq": ParamDef((d, h, dh), he_normal((-3,)), _head_spec(h, tp_size), dt),
        "wk": ParamDef((d, kv, dh), he_normal((-3,)), _head_spec(kv, tp_size), dt),
        "wv": ParamDef((d, kv, dh), he_normal((-3,)), _head_spec(kv, tp_size), dt),
        "wo": ParamDef(
            (h, dh, d),
            he_normal((-3, -2)),
            ("model", None, None) if h % tp_size == 0 else (None, None, "model"),
            dt,
        ),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, dh), zeros_init(), (None, None), dt)
        defs["bk"] = ParamDef((kv, dh), zeros_init(), (None, None), dt)
        defs["bv"] = ParamDef((kv, dh), zeros_init(), (None, None), dt)
    if with_ffn:
        defs["ln2"] = _norm_defs(cfg, d)
        if cfg.n_experts:
            defs["ffn"] = moe_defs(
                d, cfg.d_ff, cfg.n_experts, n_shared=cfg.n_shared_experts,
                shard_ff=cfg.moe_shard_ff, dtype=dt,
            )
        else:
            defs["ffn"] = mlp_defs(d, cfg.d_ff, dtype=dt)
    return defs


def _qkv(p, cfg: ArchConfig, hn: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", hn, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", hn, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", hn, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def apply_attn_block(
    p,
    cfg: ArchConfig,
    h: jax.Array,
    *,
    positions: jax.Array,
    window: Optional[int],
    collect_cache: bool,
):
    """Train/prefill attention block. h: (B, S, D); positions: (B, S).

    Returns (h', cache_entry_or_None, aux_loss).
    """
    hn = _apply_norm(cfg, p["ln1"], h)
    q, k, v = _qkv(p, cfg, hn)
    sin, cos = rope(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    out = attn_lib.multihead_attention(
        q,
        k,
        v,
        q_positions=positions,
        k_positions=positions,
        causal=True,
        window=window,
        impl=cfg.attn_impl,
        chunk_size=cfg.attn_chunk,
    )
    mask = _pad_mask(cfg, p)
    if mask is not None:
        out = out * mask[None, None, :, None].astype(out.dtype)
    h = h + jnp.einsum("bshk,hkd->bsd", out, p["wo"])

    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        hn2 = _apply_norm(cfg, p["ln2"], h)
        if cfg.n_experts:
            moe_fn = (
                apply_moe_manual_ep if cfg.moe_impl == "manual_ep"
                else partial(apply_moe, buf_constraint=cfg.moe_buf_constraint)
            )
            ff, aux = moe_fn(
                p["ffn"],
                hn2,
                top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
            )
        else:
            ff = apply_mlp(p["ffn"], hn2, act=cfg.act)
        h = h + ff

    cache_entry = (k, v, positions) if collect_cache else None
    return h, cache_entry, aux


def decode_attn_block(
    p,
    cfg: ArchConfig,
    h: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cache_pos: jax.Array,
    *,
    pos: jax.Array,
    window: Optional[int],
):
    """Single-token attention block against a cache.

    h: (B, 1, D); cache_k/v: (B, slots, KV, Dh); cache_pos: (B, slots).
    """
    hn = _apply_norm(cfg, p["ln1"], h)
    q, k, v = _qkv(p, cfg, hn)
    b = h.shape[0]
    posb = jnp.broadcast_to(pos[None, None], (b, 1))
    sin, cos = rope(posb, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    cache_k, cache_v, cache_pos = attn_lib.cache_update(
        cache_k, cache_v, cache_pos, k, v, pos, ring=window is not None
    )
    out = attn_lib.decode_attention(
        q, cache_k, cache_v, cache_pos, pos=pos, window=window
    )
    mask = _pad_mask(cfg, p)
    if mask is not None:
        out = out * mask[None, None, :, None].astype(out.dtype)
    h = h + jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if "ffn" in p:
        hn2 = _apply_norm(cfg, p["ln2"], h)
        if cfg.n_experts:
            moe_fn = (
                apply_moe_manual_ep if cfg.moe_impl == "manual_ep" else apply_moe
            )
            ff, _ = moe_fn(
                p["ffn"], hn2, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor
            )
        else:
            ff = apply_mlp(p["ffn"], hn2, act=cfg.act)
        h = h + ff
    return h, (cache_k, cache_v, cache_pos)


# ---------------------------------------------------------------------------
# Model definition
# ---------------------------------------------------------------------------

def model_defs(cfg: ArchConfig, tp_size: int = 16) -> PyTree:
    dt = cfg.dtype
    defs: dict[str, Any] = {
        "embed": ParamDef((cfg.vocab, cfg.d_model), normal_init(0.02), (None, "model"), dt),
        "final_norm": _norm_defs(cfg, cfg.d_model),
        "head": ParamDef(
            (cfg.d_model, cfg.vocab), normal_init(0.02), (None, "model"), dt
        ),
    }
    if cfg.family == "ssm":
        defs["blocks"] = stack_defs(
            rwkv_block_defs(cfg.d_model, cfg.n_heads or cfg.d_model // 64, cfg.d_ff, dt),
            cfg.n_layers,
        )
    elif cfg.family == "hybrid":
        group = cfg.attn_every
        n_groups, tail = divmod(cfg.n_layers, group)
        mdefs = mamba_block_defs(cfg.d_model, cfg.ssm_state, dtype=dt)
        defs["mamba_groups"] = stack_defs(stack_defs(mdefs, group - 1), n_groups)
        defs["shared_attn"] = attn_block_defs(cfg, tp_size, with_ffn=True)
        if tail:
            defs["tail_mamba"] = stack_defs(mdefs, tail)
    else:  # dense | moe | audio | vlm
        defs["blocks"] = stack_defs(attn_block_defs(cfg, tp_size), cfg.n_layers)
    return defs


def init_model(cfg: ArchConfig, key: jax.Array, tp_size: int = 16) -> PyTree:
    return init_params(model_defs(cfg, tp_size), key)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def _embed(params, cfg: ArchConfig, tokens: jax.Array, patch_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.input_kind == "vlm" and patch_embeds is not None:
        # decode steps carry no new patches; prefill/train prepend them
        h = jnp.concatenate([patch_embeds.astype(h.dtype), h], axis=1)
    return h


def _logits(params, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    h = _apply_norm(cfg, params["final_norm"], h)
    return jnp.einsum("bsd,dv->bsv", h, params["head"])


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean CE over valid (target >= 0) positions; f32 math."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(
        lf, jnp.maximum(targets, 0)[..., None], axis=-1
    )[..., 0]
    valid = (targets >= 0).astype(jnp.float32)
    return jnp.sum((lse - tgt) * valid) / jnp.maximum(valid.sum(), 1.0)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _maybe_remat(cfg, f):
    if not cfg.remat:
        return f
    if cfg.remat_policy == "dots":
        return jax.remat(f, policy=jax.checkpoint_policies.dots_saveable)
    return jax.remat(f)


def forward(
    params,
    cfg: ArchConfig,
    tokens: jax.Array,
    *,
    patch_embeds=None,
    window: Optional[int] = None,
    collect_cache: bool = False,
):
    """Full-sequence forward.

    Returns (logits (B, S_total, V), cache_or_states_or_None, aux_loss).
    For ssm/hybrid, states are always returned (zero-initialized at entry).
    """
    h = _embed(params, cfg, tokens, patch_embeds)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family == "ssm":
        n_heads = cfg.n_heads or cfg.d_model // 64

        def body(carry, layer_p):
            st0 = RWKVState.empty(b, n_heads, cfg.d_model // n_heads, cfg.d_model, h.dtype)
            out, st = _maybe_remat(cfg, partial(
                apply_rwkv_block, n_heads=n_heads, chunk=cfg.rec_chunk
            ))(layer_p, carry, st0)
            return out, st

        h, states = jax.lax.scan(body, h, params["blocks"])
        return _logits(params, cfg, h), states, jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        return _hybrid_forward(params, cfg, h, positions, window, collect_cache)

    def attn_apply(layer_p, hh):
        return apply_attn_block(
            layer_p, cfg, hh,
            positions=positions, window=window, collect_cache=collect_cache,
        )

    def body(carry, layer_p):
        hh, aux = carry
        hh, cache_e, a = _maybe_remat(cfg, attn_apply)(layer_p, hh)
        return (hh, aux + a), cache_e

    (h, aux), cache = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["blocks"])
    return _logits(params, cfg, h), (cache if collect_cache else None), aux


def _hybrid_forward(params, cfg, h, positions, window, collect_cache):
    b = h.shape[0]
    group = cfg.attn_every
    mk_state = lambda: MambaState.empty(
        b, mamba_n_heads(cfg.d_model), cfg.ssm_state, cfg.d_model * 2, h.dtype
    )
    shared = params["shared_attn"]
    aux0 = jnp.zeros((), jnp.float32)

    def group_body(carry, group_p):
        hh, aux = carry
        m_states = []
        for i in range(group - 1):
            lp = jax.tree.map(lambda x: x[i], group_p)
            hh, st = _maybe_remat(cfg, partial(
                apply_mamba_block, d_state=cfg.ssm_state, chunk=cfg.rec_chunk
            ))(lp, hh, mk_state())
            m_states.append(st)
        hh, cache_e, a = _maybe_remat(
            cfg,
            lambda sp, hhh: apply_attn_block(
                sp, cfg, hhh,
                positions=positions, window=window, collect_cache=collect_cache,
            ),
        )(shared, hh)
        m_states = jax.tree.map(lambda *xs: jnp.stack(xs), *m_states)
        return (hh, aux + a), (m_states, cache_e)

    (h, aux), (m_states, caches) = jax.lax.scan(
        group_body, (h, aux0), params["mamba_groups"]
    )

    tail_states = None
    if "tail_mamba" in params:
        n_tail = jax.tree.leaves(params["tail_mamba"])[0].shape[0]
        tails = []
        for i in range(n_tail):
            lp = jax.tree.map(lambda x: x[i], params["tail_mamba"])
            h, st = _maybe_remat(cfg, partial(
                apply_mamba_block, d_state=cfg.ssm_state, chunk=cfg.rec_chunk
            ))(lp, h, mk_state())
            tails.append(st)
        tail_states = jax.tree.map(lambda *xs: jnp.stack(xs), *tails)

    states = {"mamba": m_states, "attn_cache": caches, "tail": tail_states}
    return _logits(params, cfg, h), states, aux


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Next-token CE (+ MoE aux).  batch: tokens/targets (B, S) [+ patch_embeds]."""
    logits, _, aux = forward(
        params, cfg, batch["tokens"], patch_embeds=batch.get("patch_embeds")
    )
    if cfg.input_kind == "vlm":
        logits = logits[:, cfg.n_patches :]
    return cross_entropy(logits, batch["targets"]) + cfg.aux_loss_weight * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Family-polymorphic decode state (exactly one field is not None)."""

    kv: Optional[tuple] = None      # (k, v, pos) each (L, B, slots, ...) stacked
    rwkv: Optional[RWKVState] = None      # leaves (L, B, ...)
    hybrid: Optional[dict] = None


def init_decode_state(
    cfg: ArchConfig, batch: int, seq_len: int, *, window: Optional[int] = None,
    tp_size: int = 1,
) -> DecodeState:
    """Zero/empty decode state sized for a ``seq_len`` context.

    ``tp_size`` matters only for ``cfg.pad_heads`` (the cache must match the
    padded kv head count)."""
    slots = min(window, seq_len) if window else seq_len
    _, kv = attn_dims(cfg, tp_size)
    kvd = (kv, cfg.head_dim)
    mk_kv = lambda n: (
        jnp.zeros((n, batch, slots) + kvd, cfg.dtype),
        jnp.zeros((n, batch, slots) + kvd, cfg.dtype),
        jnp.full((n, batch, slots), -1, jnp.int32),
    )
    if cfg.family == "ssm":
        n_heads = cfg.n_heads or cfg.d_model // 64
        st = RWKVState.empty(batch, n_heads, cfg.d_model // n_heads, cfg.d_model, cfg.dtype)
        return DecodeState(
            rwkv=jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), st
            )
        )
    if cfg.family == "hybrid":
        group = cfg.attn_every
        n_groups, tail = divmod(cfg.n_layers, group)
        mst = MambaState.empty(
            batch, mamba_n_heads(cfg.d_model), cfg.ssm_state, cfg.d_model * 2, cfg.dtype
        )
        bc = lambda lead: jax.tree.map(
            lambda x: jnp.broadcast_to(x[None] if len(lead) == 1 else x[None, None],
                                       lead + x.shape), mst
        )
        return DecodeState(
            hybrid={
                "mamba": bc((n_groups, group - 1)),
                "attn_cache": mk_kv(n_groups),
                "tail": bc((tail,)) if tail else None,
            }
        )
    return DecodeState(kv=mk_kv(cfg.n_layers))


def prefill(params, cfg: ArchConfig, tokens: jax.Array, *, patch_embeds=None):
    """Process a prompt; returns (last-token logits (B, V), DecodeState)."""
    logits, st, _ = forward(
        params, cfg, tokens, patch_embeds=patch_embeds, collect_cache=True
    )
    last = logits[:, -1]
    if cfg.family == "ssm":
        return last, DecodeState(rwkv=st)
    if cfg.family == "hybrid":
        kc = st["attn_cache"]
        # (k, v, positions) tuples from scan: k (G, B, S, KV, Dh), pos (G?, B, S)
        k, v, p = kc
        return last, DecodeState(
            hybrid={"mamba": st["mamba"], "attn_cache": (k, v, p), "tail": st["tail"]}
        )
    k, v, p = st
    return last, DecodeState(kv=(k, v, p))


def decode_step(
    params,
    cfg: ArchConfig,
    tokens: jax.Array,
    pos: jax.Array,
    state: DecodeState,
    *,
    window: Optional[int] = None,
):
    """One token for every sequence in the batch.

    tokens: (B, 1); pos: scalar int32 (current absolute position).
    Returns (logits (B, V), new DecodeState).
    """
    h = _embed(params, cfg, tokens)  # (B, 1, D)

    if cfg.family == "ssm":
        n_heads = cfg.n_heads or cfg.d_model // 64

        def body(carry, xs):
            layer_p, st = xs
            out, st2 = rwkv_block_decode(layer_p, carry, st, n_heads=n_heads)
            return out, st2

        h1, new_states = jax.lax.scan(body, h[:, 0], (params["blocks"], state.rwkv))
        logits = _logits(params, cfg, h1[:, None])[:, 0]
        return logits, DecodeState(rwkv=new_states)

    if cfg.family == "hybrid":
        return _hybrid_decode(params, cfg, h, pos, state, window)

    def body(carry, xs):
        layer_p, ck, cv, cp = xs
        out, (ck, cv, cp) = decode_attn_block(
            layer_p, cfg, carry, ck, cv, cp, pos=pos, window=window
        )
        return out, (ck, cv, cp)

    k, v, p = state.kv
    h, new_kv = jax.lax.scan(body, h, (params["blocks"], k, v, p))
    logits = _logits(params, cfg, h)[:, 0]
    return logits, DecodeState(kv=new_kv)


def _hybrid_decode(params, cfg, h, pos, state, window):
    group = cfg.attn_every
    shared = params["shared_attn"]
    hst = state.hybrid

    def group_body(carry, xs):
        group_p, m_st, ck, cv, cp = xs
        hh = carry
        new_m = []
        for i in range(group - 1):
            lp = jax.tree.map(lambda x: x[i], group_p)
            st = jax.tree.map(lambda x: x[i], m_st)
            hh1, st2 = mamba_block_decode(lp, hh[:, 0], st, d_state=cfg.ssm_state)
            hh = hh1[:, None]
            new_m.append(st2)
        hh, (ck, cv, cp) = decode_attn_block(
            shared, cfg, hh, ck, cv, cp, pos=pos, window=window
        )
        new_m = jax.tree.map(lambda *xs_: jnp.stack(xs_), *new_m)
        return hh, (new_m, ck, cv, cp)

    k, v, p = hst["attn_cache"]
    h, (new_m, nk, nv, np_) = jax.lax.scan(
        group_body, h, (params["mamba_groups"], hst["mamba"], k, v, p)
    )

    new_tail = None
    if hst.get("tail") is not None:
        n_tail = jax.tree.leaves(hst["tail"])[0].shape[0]
        tails = []
        for i in range(n_tail):
            lp = jax.tree.map(lambda x: x[i], params["tail_mamba"])
            st = jax.tree.map(lambda x: x[i], hst["tail"])
            h1, st2 = mamba_block_decode(lp, h[:, 0], st, d_state=cfg.ssm_state)
            h = h1[:, None]
            tails.append(st2)
        new_tail = jax.tree.map(lambda *xs_: jnp.stack(xs_), *tails)

    logits = _logits(params, cfg, h)[:, 0]
    return logits, DecodeState(
        hybrid={"mamba": new_m, "attn_cache": (nk, nv, np_), "tail": new_tail}
    )
