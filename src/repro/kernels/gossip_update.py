"""Fused gossip-apply kernel: momentum-SGD step + weighted neighbor average.

The decentralized inner loop ends with three elementwise passes over the
full parameter vector (optimizer update, then the weighted sum of self +
deg neighbor buffers delivered by the collective-permutes).  Unfused that
costs ``(deg + 5)`` HBM reads + 3 writes of P; this kernel fuses it into
``(deg + 3)`` reads + 2 writes with one VMEM-tiled pass:

    m'     = beta * m + g
    theta* = theta - lr * m'
    theta' = w_0 * theta* + Σ_i w_i * n_i

Layout: parameters are flattened and blocked 1-D ((block,) VMEM tiles,
8·128-aligned); neighbor buffers arrive stacked (deg, P) — on TPU these are
the ppermute landing buffers, so no extra copy.  Weights live in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gossip_update"]


def _kernel(w_ref, theta_ref, nbr_ref, grad_ref, mom_ref, out_ref, mom_out_ref,
            *, lr: float, beta: float, deg: int):
    g = grad_ref[...].astype(jnp.float32)
    m_new = beta * mom_ref[...].astype(jnp.float32) + g
    local = theta_ref[...].astype(jnp.float32) - lr * m_new
    acc = w_ref[0] * local
    for i in range(deg):
        acc += w_ref[i + 1] * nbr_ref[i].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)
    mom_out_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=("lr", "beta", "block", "interpret"))
def gossip_update(
    theta: jax.Array,      # (P,)
    neighbors: jax.Array,  # (deg, P)
    weights: jax.Array,    # (deg + 1,) [self, n_1..n_deg]
    grad: jax.Array,       # (P,)
    momentum: jax.Array,   # (P,) float32
    *,
    lr: float,
    beta: float,
    block: int = 1024,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (theta', m')."""
    (p,) = theta.shape
    deg = neighbors.shape[0]
    block = min(block, p)
    if p % block:
        raise ValueError(f"param length {p} must tile by block {block}")
    grid = (p // block,)
    out, m_out = pl.pallas_call(
        functools.partial(_kernel, lr=lr, beta=beta, deg=deg),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # weights
            pl.BlockSpec((block,), lambda i: (i,)),          # theta
            pl.BlockSpec((deg, block), lambda i: (0, i)),    # neighbors
            pl.BlockSpec((block,), lambda i: (i,)),          # grad
            pl.BlockSpec((block,), lambda i: (i,)),          # momentum
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), theta.dtype),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=interpret,
    )(weights.astype(jnp.float32), theta, neighbors, grad, momentum)
    return out, m_out
