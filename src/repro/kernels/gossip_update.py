"""Fused gossip-apply kernels: momentum-SGD step + weighted neighbor average.

The decentralized inner loop ends with three elementwise passes over the
full parameter vector (optimizer update, then the weighted sum of self +
deg neighbor buffers delivered by the collective-permutes).  Unfused that
costs ``(deg + 5)`` HBM reads + 3 writes of P; this kernel fuses it into
``(deg + 3)`` reads + 2 writes with one VMEM-tiled pass:

    m'     = beta * m + g
    theta* = theta - lr * m'
    theta' = w_0 * theta* + Σ_i w_i * n_i         (mix_order="post")

(or, for ``mix_order="pre"``, mix the raw params first and descend after:
``theta' = w_0·theta + Σ_i w_i·n_i − lr·m'``, which needs no pre-send
materialization of theta*).

Two granularities share one kernel body:

  * ``gossip_update``          — one node: theta (P,), neighbors (deg, P),
    weights (deg+1,) in SMEM.  The original single-replica entry point.
  * ``gossip_program_update``  — a whole stacked replica axis: theta
    (n, P), neighbors (n, deg, P), per-node weights (n, deg+1); the grid
    runs (node, block) and each node's (deg+1,) weight row is selected
    into SMEM by the BlockSpec index map.  This is the executor for
    compiled PPermute programs (circulant offsets, matchings, and
    edge-colored irregular graphs alike) — ``fused_apply_stacked`` feeds
    it straight from a ``GossipProgram``.

``lr``/``beta`` ride in a (2,) SMEM vector at *runtime* — LR schedules do
not retrigger compiles — and ``interpret`` auto-detects the backend
(compiled on TPU, interpreter elsewhere).  The per-node weight row is a
runtime operand too, and a second per-node (deg+1,) SMEM *fault row*
``[update, edge_1..edge_deg]`` gates the local update (stragglers/dead)
and masks permute edges, renormalizing dropped weight onto self in-kernel
(``degraded_matrix`` semantics): one executable serves every transient
fault realization, and the all-ones row reproduces the fault-free math
bit-for-bit.  The same row carries the elastic extremes: a *ghost* rank
(``faults.SparePool`` spare — all-zero row) degrades to the identity and
idles until its activation flips the row live, and a *deadline-benched*
straggler keeps ``update = 1`` with edges masked — it descends locally
while sitting out the gossip round.

Layout: parameters are flattened and blocked 1-D ((block,) VMEM tiles,
8·128-aligned); neighbor buffers arrive stacked (deg, P) — on TPU these are
the ppermute landing buffers, so no extra copy.  Weights live in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "gossip_update",
    "gossip_program_update",
    "fused_apply_stacked",
    "fused_apply_shard",
    "fused_bucket_update",
]


def _auto_interpret(interpret):
    """Compiled Pallas on TPU; interpreter everywhere else (exact semantics,
    so CPU tests stay bit-meaningful)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _auto_block(block, interpret):
    """Default tile: 1024 (8·128-aligned VMEM tile) when compiled; 2^20 in
    interpreter mode, whose grid is a host-level loop — the tile bound is
    correctness-irrelevant there and small tiles make the loop the
    bottleneck (~1 ms per grid cell on CPU)."""
    if block is not None:
        return block
    return (1 << 20) if interpret else 1024


def _check_budget(deg, block, interpret):
    """Validate the dispatch signature against the documented SMEM/VMEM
    budgets (``analysis/budget.py``) before building the Pallas call.
    The check is lru-cached per (deg, block) signature over there, so the
    hot path pays one dict lookup."""
    from repro.analysis.budget import check_kernel_budget

    check_kernel_budget(int(deg), int(block), interpret=bool(interpret))


def _mix_block(w, f, theta, nbrs, grad, mom, lr, beta, *, deg, mix_order,
               out_dtype):
    """Shared kernel math on one VMEM tile; ``w[k]`` scalar-indexes SMEM.

    ``f`` is the *fault row* accessor (SMEM, runtime): ``f(0)`` gates this
    node's local update (0 = straggler/dead: gradient discarded, momentum
    untouched) and ``f(i+1)`` masks permute round i's edge.  A dropped edge
    zeroes its weight and renormalizes IN-KERNEL — the lost mass moves onto
    the self weight, keeping the realized row stochastic — so one compiled
    executable serves every transient-fault realization (the all-ones row
    reproduces the fault-free math bit-for-bit).
    """
    g = grad.astype(jnp.float32)
    mom32 = mom.astype(jnp.float32)
    u = f(0)
    m_new = u * (beta * mom32 + g) + (1.0 - u) * mom32
    base = theta.astype(jnp.float32)
    self_w = w(0)
    for i in range(deg):
        self_w = self_w + (1.0 - f(i + 1)) * w(i + 1)
    if mix_order == "post":
        acc = self_w * (base - lr * u * m_new)
    else:  # pre: mix raw params, descend afterwards
        acc = self_w * base
    for i in range(deg):
        acc = acc + f(i + 1) * w(i + 1) * nbrs(i).astype(jnp.float32)
    if mix_order == "pre":
        acc = acc - lr * u * m_new
    return acc.astype(out_dtype), m_new


def _kernel(sc_ref, w_ref, f_ref, theta_ref, nbr_ref, grad_ref, mom_ref,
            out_ref, mom_out_ref, *, deg: int, mix_order: str):
    out, m_new = _mix_block(
        lambda k: w_ref[k], lambda k: f_ref[k], theta_ref[...],
        lambda i: nbr_ref[i], grad_ref[...], mom_ref[...],
        sc_ref[0], sc_ref[1],
        deg=deg, mix_order=mix_order, out_dtype=out_ref.dtype,
    )
    out_ref[...] = out
    mom_out_ref[...] = m_new


def _program_kernel(sc_ref, w_ref, f_ref, theta_ref, nbr_ref, grad_ref,
                    mom_ref, out_ref, mom_out_ref, *, deg: int, mix_order: str):
    out, m_new = _mix_block(
        lambda k: w_ref[0, k], lambda k: f_ref[0, k], theta_ref[0],
        lambda i: nbr_ref[0, i], grad_ref[0], mom_ref[0],
        sc_ref[0], sc_ref[1],
        deg=deg, mix_order=mix_order, out_dtype=out_ref.dtype,
    )
    out_ref[0] = out
    mom_out_ref[0] = m_new


@functools.partial(
    jax.jit, static_argnames=("block", "interpret", "mix_order")
)
def _gossip_update(theta, neighbors, weights, fault, grad, momentum, scalars,
                   *, block: int, interpret: bool, mix_order: str):
    (p,) = theta.shape
    deg = neighbors.shape[0]
    block = min(block, p)
    if p % block:
        raise ValueError(f"param length {p} must tile by block {block}")
    grid = (p // block,)
    return pl.pallas_call(
        functools.partial(_kernel, deg=deg, mix_order=mix_order),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),           # [lr, beta]
            pl.BlockSpec(memory_space=pltpu.SMEM),           # weights
            pl.BlockSpec(memory_space=pltpu.SMEM),           # fault row
            pl.BlockSpec((block,), lambda i: (i,)),          # theta
            pl.BlockSpec((deg, block), lambda i: (0, i)),    # neighbors
            pl.BlockSpec((block,), lambda i: (i,)),          # grad
            pl.BlockSpec((block,), lambda i: (i,)),          # momentum
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), theta.dtype),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, weights.astype(jnp.float32), fault.astype(jnp.float32),
      theta, neighbors, grad, momentum)


def gossip_update(
    theta: jax.Array,      # (P,)
    neighbors: jax.Array,  # (deg, P)
    weights: jax.Array,    # (deg + 1,) [self, n_1..n_deg]
    grad: jax.Array,       # (P,)
    momentum: jax.Array,   # (P,) float32
    *,
    lr,
    beta,
    fault: jax.Array | None = None,  # (deg + 1,) [update, edge_1..edge_deg]
    block: int | None = None,
    interpret: bool | None = None,
    mix_order: str = "post",
) -> tuple[jax.Array, jax.Array]:
    """Returns (theta', m').  lr/beta/weights/fault are runtime values — LR
    schedules, degraded weight rows, and fault masks never recompile."""
    interpret = _auto_interpret(interpret)
    scalars = jnp.stack(
        [jnp.asarray(lr, jnp.float32), jnp.asarray(beta, jnp.float32)]
    )
    if fault is None:
        fault = jnp.ones((neighbors.shape[0] + 1,), jnp.float32)
    return _gossip_update(
        theta, neighbors, weights, fault, grad, momentum, scalars,
        block=_auto_block(block, interpret), interpret=interpret,
        mix_order=mix_order,
    )


@functools.partial(
    jax.jit, static_argnames=("block", "interpret", "mix_order")
)
def _gossip_program_update(theta, neighbors, weights, fault, grad, momentum,
                           scalars, *, block: int, interpret: bool,
                           mix_order: str):
    n, p = theta.shape
    deg = neighbors.shape[1]
    block = min(block, p)
    if p % block:
        raise ValueError(f"param length {p} must tile by block {block}")
    grid = (n, p // block)
    return pl.pallas_call(
        functools.partial(_program_kernel, deg=deg, mix_order=mix_order),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),              # [lr, beta]
            # this node's (deg+1,) weight row, selected into SMEM per node
            pl.BlockSpec((1, deg + 1), lambda i, j: (i, 0),
                         memory_space=pltpu.SMEM),
            # this node's (deg+1,) fault row [update, edge_1..edge_deg]
            pl.BlockSpec((1, deg + 1), lambda i, j: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),       # theta
            pl.BlockSpec((1, deg, block), lambda i, j: (i, 0, j)),  # nbrs
            pl.BlockSpec((1, block), lambda i, j: (i, j)),       # grad
            pl.BlockSpec((1, block), lambda i, j: (i, j)),       # momentum
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), theta.dtype),
            jax.ShapeDtypeStruct((n, p), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, weights.astype(jnp.float32), fault.astype(jnp.float32),
      theta, neighbors, grad, momentum)


def gossip_program_update(
    theta: jax.Array,      # (n, P) stacked replicas
    neighbors: jax.Array,  # (n, deg, P) permute landing buffers
    weights: jax.Array,    # (n, deg + 1) per-node [self, w_1..w_deg]
    grad: jax.Array,       # (n, P)
    momentum: jax.Array,   # (n, P) float32
    *,
    lr,
    beta,
    fault: jax.Array | None = None,  # (n, deg + 1) [update, edge_1..edge_deg]
    block: int | None = None,
    interpret: bool | None = None,
    mix_order: str = "post",
) -> tuple[jax.Array, jax.Array]:
    """Per-node-weight program executor over the stacked axis.

    ``weights`` and ``fault`` are runtime operands: degraded weight rows
    and per-realization edge/update masks reuse the one cached executable
    (the zero-recompile invariant under faults).
    """
    interpret = _auto_interpret(interpret)
    scalars = jnp.stack(
        [jnp.asarray(lr, jnp.float32), jnp.asarray(beta, jnp.float32)]
    )
    if fault is None:
        fault = jnp.ones(
            (theta.shape[0], neighbors.shape[1] + 1), jnp.float32
        )
    return _gossip_program_update(
        theta, neighbors, weights, fault, grad, momentum, scalars,
        block=_auto_block(block, interpret), interpret=interpret,
        mix_order=mix_order,
    )


# ---------------------------------------------------------------------------
# Program-level glue: one decentralized SGD round for stacked pytrees
# ---------------------------------------------------------------------------

def _flatten_stacked(tree, n):
    leaves = jax.tree.leaves(tree)
    flat = [x.reshape(n, -1) for x in leaves]
    sizes = [f.shape[1] for f in flat]
    return jnp.concatenate(flat, axis=1), sizes


def _unflatten_stacked(mat, tree, sizes):
    leaves = jax.tree.leaves(tree)
    out, off = [], 0
    for leaf, size in zip(leaves, sizes):
        out.append(mat[:, off:off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(jax.tree.structure(tree), out)


def _fault_rows_stacked(fault, srcs, n):
    """(n, deg+1) kernel fault rows [update, edge_1..deg] from runtime masks.

    ``fault`` is the engines' mask pytree ({"update", "alive", "link"});
    edge k of node i is up iff both endpoints are alive and the link
    survives.  Idle slots (srcs[i, k] == i) carry zero weight, so their
    mask value is irrelevant.
    """
    af = fault["alive"].astype(jnp.float32)
    m = af[jnp.asarray(srcs)] * af[:, None]
    link = fault.get("link")
    if link is not None:
        m = m * link.astype(jnp.float32)[
            jnp.arange(n)[:, None], jnp.asarray(srcs)
        ]
    u = fault["update"].astype(jnp.float32)
    return jnp.concatenate([u[:, None], m], axis=1)


def fused_apply_stacked(
    program,
    params,     # pytree, leaves (n, ...)
    grads,      # matching pytree
    momentum,   # matching pytree (float32), or () when beta == 0
    *,
    lr,
    beta,
    fault=None,  # {"update": (n,), "alive": (n,), "link": (n, n)} or None
    mix_order: str = "post",
    block: int | None = None,
    interpret: bool | None = None,
):
    """One fused momentum-SGD + gossip round for a compiled PPermute program.

    Flattens the stacked trees to (n, P) (zero-padded to a block multiple),
    gathers each node's neighbor landing buffers per the program's
    ``permute_tables`` — for ``mix_order="post"`` the wire carries the
    *post-update* θ\\*, for ``"pre"`` the raw θ, so nothing extra is
    materialized — and runs ``gossip_program_update``.  Returns
    ``(new_params, new_momentum)`` with the input tree structure.

    ``fault`` carries runtime masks (``core/faults.realization_arrays``):
    straggling/dead nodes skip the update, dropped edges renormalize onto
    self inside the kernel — same executable for every realization.

    Raises ``ValueError`` for programs with non-permute ops (AllReduce /
    GatherRow / fused multi-round): those keep the interpreter path.
    """
    tables = program.permute_tables()
    if tables is None:
        raise ValueError(
            f"program {program.name!r} is not an all-PPermute single round; "
            "fused apply supports permute programs only"
        )
    srcs, weights = tables
    interpret = _auto_interpret(interpret)
    block = _auto_block(block, interpret)
    n = program.n
    theta, sizes = _flatten_stacked(params, n)
    g_mat, _ = _flatten_stacked(grads, n)
    if momentum == () or momentum is None:
        m_mat = jnp.zeros(theta.shape, jnp.float32)
        had_momentum = False
    else:
        m_mat, _ = _flatten_stacked(momentum, n)
        had_momentum = True
    p = theta.shape[1]
    block = min(block, p)
    _check_budget(srcs.shape[1], block, interpret)
    pad = (-p) % block
    if pad:
        theta = jnp.pad(theta, ((0, 0), (0, pad)))
        g_mat = jnp.pad(g_mat, ((0, 0), (0, pad)))
        m_mat = jnp.pad(m_mat, ((0, 0), (0, pad)))

    lr32 = jnp.asarray(lr, jnp.float32)
    beta32 = jnp.asarray(beta, jnp.float32)
    fault_rows = None if fault is None else _fault_rows_stacked(fault, srcs, n)
    if mix_order == "post":
        # the buffers on the wire are the senders' post-update params
        m_wire = beta32 * m_mat + g_mat.astype(jnp.float32)
        if fault is not None:  # stragglers/dead send their un-updated params
            m_wire = m_wire * fault["update"].astype(jnp.float32)[:, None]
        wire = (theta.astype(jnp.float32) - lr32 * m_wire).astype(theta.dtype)
    else:
        wire = theta
    # (n, deg) fancy index along the node axis -> (n, deg, P) landing buffers
    nbrs = jnp.take(wire, jnp.asarray(srcs), axis=0)

    out, m_new = gossip_program_update(
        theta, nbrs, jnp.asarray(weights), g_mat, m_mat,
        lr=lr32, beta=beta32, fault=fault_rows, block=block,
        interpret=interpret, mix_order=mix_order,
    )
    if pad:
        out = out[:, :p]
        m_new = m_new[:, :p]
    new_params = _unflatten_stacked(out, params, sizes)
    if not had_momentum:
        return new_params, ()
    return new_params, _unflatten_stacked(m_new, momentum, sizes)


def fused_bucket_update(
    program,
    theta_b,    # (n, w_b) one bucket's stacked slice (BucketLayout view)
    grad_b,     # (n, w_b)
    mom_b,      # (n, w_b) float32 (zeros when the optimizer is momentum-free)
    *,
    lr,
    beta,
    fault=None,  # {"update": (n,), "alive": (n,), "link": (n, n)} or None
    mix_order: str = "post",
    block: int | None = None,
    interpret: bool | None = None,
):
    """One bucket's fused SGD + gossip round on raw (n, w_b) matrices.

    The bucket boundary is the kernel's *outer dispatch unit*: the engines
    slice the flattened tree with a ``BucketLayout`` and call this once per
    bucket, so bucket i's permute-landing gathers and kernel pass carry no
    data dependency on bucket i+1's — the dispatches pipeline.  Inside,
    the (node, block) grid of ``gossip_program_update`` runs unchanged over
    the bucket's width, and each node's (deg+1,) SMEM weight/fault rows are
    byte-identical across buckets (width never enters them), so the rows
    are re-selected, never re-built, per bucket.  Skips the pytree
    flatten/unflatten of ``fused_apply_stacked`` — the layout already did
    it once for all buckets.  Returns ``(theta_b', mom_b')``.
    """
    tables = program.permute_tables()
    if tables is None:
        raise ValueError(
            f"program {program.name!r} is not an all-PPermute single round; "
            "fused apply supports permute programs only"
        )
    srcs, weights = tables
    interpret = _auto_interpret(interpret)
    block = _auto_block(block, interpret)
    n = program.n
    theta = theta_b
    g_mat = grad_b
    m_mat = mom_b.astype(jnp.float32)
    p = theta.shape[1]
    block = min(block, max(p, 1))
    _check_budget(srcs.shape[1], block, interpret)
    pad = (-p) % block
    if pad:
        theta = jnp.pad(theta, ((0, 0), (0, pad)))
        g_mat = jnp.pad(g_mat, ((0, 0), (0, pad)))
        m_mat = jnp.pad(m_mat, ((0, 0), (0, pad)))

    lr32 = jnp.asarray(lr, jnp.float32)
    beta32 = jnp.asarray(beta, jnp.float32)
    fault_rows = None if fault is None else _fault_rows_stacked(fault, srcs, n)
    if mix_order == "post":
        m_wire = beta32 * m_mat + g_mat.astype(jnp.float32)
        if fault is not None:
            m_wire = m_wire * fault["update"].astype(jnp.float32)[:, None]
        wire = (theta.astype(jnp.float32) - lr32 * m_wire).astype(theta.dtype)
    else:
        wire = theta
    nbrs = jnp.take(wire, jnp.asarray(srcs), axis=0)

    out, m_new = gossip_program_update(
        theta, nbrs, jnp.asarray(weights), g_mat, m_mat,
        lr=lr32, beta=beta32, fault=fault_rows, block=block,
        interpret=interpret, mix_order=mix_order,
    )
    if pad:
        out = out[:, :p]
        m_new = m_new[:, :p]
    return out, m_new


def _flatten_local(tree):
    leaves = jax.tree.leaves(tree)
    flat = [x.reshape(-1) for x in leaves]
    return jnp.concatenate(flat), [f.shape[0] for f in flat]


def _unflatten_local(vec, tree, sizes):
    leaves = jax.tree.leaves(tree)
    out, off = [], 0
    for leaf, size in zip(leaves, sizes):
        out.append(vec[off:off + size].reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree.unflatten(jax.tree.structure(tree), out)


def fused_apply_shard(
    program,
    params,     # pytree of THIS node's values (inside shard_map)
    grads,
    momentum,   # matching pytree (float32), or () when beta == 0
    axis_names,
    *,
    lr,
    beta,
    fault=None,  # {"update": (n,), "alive": (n,), "link": (n, n)} or None
    mix_order: str = "post",
    block: int | None = None,
    interpret: bool | None = None,
):
    """The production-path twin of ``fused_apply_stacked``: one fused
    momentum-SGD + gossip round on per-node values inside ``shard_map``.

    One ``jax.lax.ppermute`` per compiled permute delivers the neighbor
    landing buffers (non-participating nodes receive zeros, matching the
    zero weight in their SMEM row); this node's (deg+1,) weight row is
    selected by its flat axis index.  ``fault`` carries the replicated
    runtime masks — this node slices its own update flag and edge-mask row,
    so every realization reuses the one executable.  Returns
    ``(new_params, new_momentum)``.
    """
    from repro.core.schedule import _flat_axis_index  # avoid import cycle

    tables = program.permute_tables()
    if tables is None:
        raise ValueError(
            f"program {program.name!r} is not an all-PPermute single round; "
            "fused apply supports permute programs only"
        )
    srcs, weights = tables
    interpret = _auto_interpret(interpret)
    block = _auto_block(block, interpret)
    theta, sizes = _flatten_local(params)
    g_vec, _ = _flatten_local(grads)
    if momentum == () or momentum is None:
        m_vec = jnp.zeros(theta.shape, jnp.float32)
        had_momentum = False
    else:
        m_vec, _ = _flatten_local(momentum)
        had_momentum = True
    p = theta.shape[0]
    block = min(block, p)
    _check_budget(srcs.shape[1], block, interpret)
    pad = (-p) % block
    if pad:
        theta = jnp.pad(theta, (0, pad))
        g_vec = jnp.pad(g_vec, (0, pad))
        m_vec = jnp.pad(m_vec, (0, pad))

    idx = _flat_axis_index(axis_names)
    lr32 = jnp.asarray(lr, jnp.float32)
    beta32 = jnp.asarray(beta, jnp.float32)
    frow = None
    if fault is not None:
        # this node's row of the shared edge-up mask formula
        frow = _fault_rows_stacked(fault, srcs, srcs.shape[0])[idx]
    if mix_order == "post":
        m_wire = beta32 * m_vec + g_vec.astype(jnp.float32)
        if fault is not None:
            m_wire = m_wire * frow[0]
        wire = (theta.astype(jnp.float32) - lr32 * m_wire).astype(theta.dtype)
    else:
        wire = theta
    nbrs = jnp.stack(
        [jax.lax.ppermute(wire, axis_names, list(op.perm)) for op in program.ops]
    )
    wrow = jnp.asarray(weights)[idx]
    out, m_new = gossip_update(
        theta, nbrs, wrow, g_vec, m_vec,
        lr=lr32, beta=beta32, fault=frow, block=block, interpret=interpret,
        mix_order=mix_order,
    )
    if pad:
        out = out[:p]
        m_new = m_new[:p]
    new_params = _unflatten_local(out, params, sizes)
    if not had_momentum:
        return new_params, ()
    return new_params, _unflatten_local(m_new, momentum, sizes)
