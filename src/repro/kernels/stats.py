"""Blocked L2-norm reduction kernel (the DBench in-step probe).

DBench reads the L2 norm of every parameter tensor on every node each
iteration (paper §3.1.2, ``torch.tensor.norm()``).  At 10⁹-parameter scale
that probe is itself a full HBM sweep, so it gets a kernel: rows are
reduced block-by-block into an SMEM accumulator (f32), one grid row per
tensor.  Layout: tensors are flattened and zero-padded into an (R, P) matrix
(R = number of probed tensors); zero padding does not change an L2 norm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["l2_norms"]


def _kernel(x_ref, o_ref, acc_ref, *, nblocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[0] = 0.0

    x = x_ref[0].astype(jnp.float32)
    acc_ref[0] += jnp.sum(x * x)

    @pl.when(j == nblocks - 1)
    def _fin():
        o_ref[0] = jnp.sqrt(acc_ref[0])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def l2_norms(x: jax.Array, *, block: int = 2048, interpret: bool = True) -> jax.Array:
    """Row L2 norms of (R, P) -> (R,) float32."""
    r, p = x.shape
    block = min(block, p)
    if p % block:
        pad = (-p) % block
        x = jnp.pad(x, ((0, 0), (0, pad)))
        p += pad
    nblocks = p // block
    return pl.pallas_call(
        functools.partial(_kernel, nblocks=nblocks),
        grid=(r, nblocks),
        in_specs=[pl.BlockSpec((1, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32)],
        interpret=interpret,
    )(x)
