"""Pallas TPU kernels for the compute hot-spots (validated in interpret mode).

flash_attention  online-softmax attention, MXU-aligned VMEM tiles, GQA/window
gossip_update    fused momentum-SGD + weighted neighbor average (gossip apply)
stats            blocked L2-norm reduction (the DBench per-tensor probe)

Each has a pure-jnp oracle in ``ref.py``; ``ops.py`` holds the public jitted
wrappers (interpret=True automatically off-TPU).
"""
from repro.kernels import ops, ref
from repro.kernels.ops import flash_attention, gossip_update, l2_norms
