"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "gossip_update_ref", "l2_norms_ref"]


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.reshape(b, kv, group, sq, d).astype(jnp.float32) / math.sqrt(d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows -> zero output
    p = jnp.where(mask.any(-1)[None, None, None, :, None], p, 0.0)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def gossip_update_ref(
    theta: jax.Array,       # (P,) this node's post-backward params
    neighbors: jax.Array,   # (deg, P) neighbor params (post their updates)
    weights: jax.Array,     # (deg + 1,): [self, n_1, ..., n_deg]
    grad: jax.Array,        # (P,)
    momentum: jax.Array,    # (P,)
    *,
    lr: float,
    beta: float,
) -> tuple[jax.Array, jax.Array]:
    """Fused decentralized-SGD apply:

      m'     = beta * m + g
      theta* = theta - lr * m'          (local descent)
      theta' = w_0 * theta* + sum_i w_i * n_i   (gossip average)
    """
    tf = theta.astype(jnp.float32)
    m_new = beta * momentum.astype(jnp.float32) + grad.astype(jnp.float32)
    local = tf - lr * m_new
    mixed = weights[0] * local + jnp.einsum(
        "n,np->p", weights[1:].astype(jnp.float32), neighbors.astype(jnp.float32)
    )
    return mixed.astype(theta.dtype), m_new


def l2_norms_ref(x: jax.Array) -> jax.Array:
    """Row L2 norms of a (R, P) matrix -> (R,) float32 (DBench probe)."""
    return jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1))
