"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on non-TPU backends (this container is
CPU-only; interpret mode executes the kernel bodies exactly, so tests are
bit-meaningful) and False on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gossip_update import gossip_update as _gossip
from repro.kernels.stats import l2_norms as _l2

__all__ = [
    "flash_attention",
    "gossip_update",
    "gossip_program_update",
    "l2_norms",
    "default_interpret",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, block_q=128, block_k=128,
                    interpret=None):
    """(B, H, Sq, D) x (B, KV, Sk, D)² -> (B, H, Sq, D)."""
    itp = default_interpret() if interpret is None else interpret
    return _flash(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=itp,
    )


def gossip_update(theta, neighbors, weights, grad, momentum, *, lr, beta,
                  block=1024, interpret=None, mix_order="post"):
    """lr/beta are runtime scalars (LR schedules do not retrigger compiles);
    interpret=None auto-detects the backend inside the kernel module."""
    return _gossip(
        theta, neighbors, weights, grad, momentum,
        lr=lr, beta=beta, block=block, interpret=interpret,
        mix_order=mix_order,
    )


def gossip_program_update(theta, neighbors, weights, grad, momentum, *, lr,
                          beta, block=1024, interpret=None, mix_order="post"):
    """(n, P) stacked executor with per-node (deg+1,) SMEM weight rows."""
    from repro.kernels.gossip_update import gossip_program_update as _prog

    return _prog(
        theta, neighbors, weights, grad, momentum,
        lr=lr, beta=beta, block=block, interpret=interpret,
        mix_order=mix_order,
    )


def l2_norms(x, *, block=2048, interpret=None):
    itp = default_interpret() if interpret is None else interpret
    return _l2(x, block=block, interpret=itp)
