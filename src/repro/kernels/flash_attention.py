"""Pallas TPU flash-attention kernel (causal, GQA, optional sliding window).

TPU-native adaptation (DESIGN.md §2): the CUDA flash-attention tiling is
re-expressed for the TPU memory hierarchy — HBM→VMEM block streaming with
MXU-aligned (128×128) tiles and an online-softmax accumulator held in VMEM
scratch across the sequential K grid dimension.  One grid step computes one
(q-block × k-block) tile; the K dimension is the innermost ("arbitrary")
grid axis so the scratch accumulators carry across it.

Layouts:
  q:    (B, H, Sq, D)
  k/v:  (B, KV, Sk, D)      (GQA: KV | H, mapped via h // (H // KV))
  out:  (B, H, Sq, D)

Validated against ``ref.flash_attention_ref`` in interpret mode on CPU
(tests/test_kernels.py); on real TPUs pass ``interpret=False``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None, bq: int, bk: int, nk: int
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                   # (bq, bk)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        m_safe = jnp.where(m_new <= _NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(m_prev <= _NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_scr[...] = l_prev * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        # whole block strictly above the diagonal: nothing to do
        pl.when(ki * bk <= qi * bq + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) with H % KV == 0 -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    group = h // kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq lens ({sq},{sk}) must tile by ({bq},{bk})")
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),   # running max m
            pltpu.VMEM((bq,), jnp.float32),   # running sum l
            pltpu.VMEM((bq, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out
