"""repro: decentralized data-parallel training at scale (Ada + DBench) in JAX."""
__version__ = "0.1.0"
